"""Deterministic tests for the host-RAM page tier (docs/ROBUSTNESS.md,
"memory tiers"): the pinned host pool behind ``--host-tier``, the page
movers that DMA pages across it, and the engine seams that use it.

Covers, over the closed-form stub model (tests/serving_stub.py):

* HostPageTier unit behavior: digest round trips, verify-at-take
  (corruption and kind mismatches raise ``PageCorruptionError`` and the
  entry is consumed either way), pinned entries surviving LRU eviction,
  capacity/byte accounting;
* page movers: kv/state fetch→put→take→insert round trips are BITWISE
  across leaf dtypes — the swap path never requantizes in flight;
* host prefix hits: parked pages demoted to host RAM serve later
  identical prompts bit-identically (swap-in to a fresh pid);
* preempt→swap→resume: a preempted decoder rejoins decode from host
  page snapshots with outputs exactly equal to an uninterrupted run —
  including a double preemption (the ``_orig_plen`` fold regression);
* fault seams: ``swap_out`` refusals fall back to recompute, ``swap_in``
  refusals drop the carry and recompute, ``swap_corrupt`` quarantines
  ONLY the owning request while batchmates finish exact;
* pressure: a tier too small for the carry skips the swap (plain
  recompute), a disabled tier (host_pages=0) never swaps at all;
* the recompression ladder: int8 is exact for the stub's integer
  payloads, the stage marker travels through the host tier as metadata.

The state-layout engine seams (zero-replay resume) live with the other
state tests in test_state_paged.py, which caches the real-model builds.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from serving_stub import VOCAB, expected_greedy, make_stub_api

from repro.serving import pages as pages_lib
from repro.serving.engine import PagedEngine
from repro.serving.faults import FaultInjector
from repro.serving.generate import Request
from repro.serving.pages import (
    KIND_KV,
    KIND_STATE,
    HostPageTier,
    PageCorruptionError,
)

STUB = make_stub_api()


def _mk_engine(faults=None, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("n_pages", 24)
    kw.setdefault("chunked_prefill", True)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("host_pages", 16)
    return PagedEngine(STUB, {}, fault_injector=faults, **kw)


def _req(rid, plen, max_new=3, **kw):
    prompt = ((np.arange(plen) + rid) % VOCAB).astype(np.int32)
    return Request(rid=rid, prompt=prompt, max_new=max_new, **kw)


def _no_referenced_pages(eng):
    return int((eng.pool_mgr.refcount > 0).sum()) == 0


def _swap(eng):
    return {k: c.value for k, c in eng._cs_swap.items()}


def _step_until_decoding(eng, req, min_out=2, max_ticks=30):
    """Tick until the request has produced min_out decode tokens, then
    drain the launch pipeline so a preemption sees a settled slot."""
    for _ in range(max_ticks):
        eng.step()
        if len(req.out) >= min_out:
            break
    eng.drain()
    assert len(req.out) >= min_out
    return len(req.out)


# ------------------------------------------------------------- tier unit
class TestHostPageTier:
    def test_put_take_round_trip_and_accounting(self):
        tier = HostPageTier(4)
        a = np.arange(12, dtype=np.float32).reshape(3, 4)
        b = np.arange(6, dtype=np.int32)
        h = tier.put([a, b], KIND_KV, meta={"rid": 7})
        assert h >= pages_lib._HANDLE_BASE
        assert tier.used() == 1 and tier.has(h)
        assert tier.kind_of(h) == KIND_KV
        assert tier.bytes_resident == a.nbytes + b.nbytes
        entry = tier.take(h, expect_kind=KIND_KV)
        np.testing.assert_array_equal(entry.arrays[0], a)
        np.testing.assert_array_equal(entry.arrays[1], b)
        assert entry.meta["rid"] == 7
        # take CONSUMES: the entry is gone, bytes are released
        assert not tier.has(h) and tier.used() == 0
        assert tier.bytes_resident == 0

    def test_put_copies_the_payload(self):
        tier = HostPageTier(2)
        a = np.zeros(4, np.float32)
        h = tier.put([a], KIND_KV)
        a[:] = 9.0  # caller mutates its buffer after the put
        entry = tier.take(h)
        np.testing.assert_array_equal(entry.arrays[0], np.zeros(4, np.float32))

    def test_corruption_detected_and_entry_consumed(self):
        tier = HostPageTier(2)
        h = tier.put([np.arange(8, dtype=np.float32)], KIND_KV)
        tier.corrupt(h)
        with pytest.raises(PageCorruptionError) as ei:
            tier.take(h)
        assert "integrity" in str(ei.value)
        # even a failed take consumes the entry: corrupt bytes never
        # survive to be re-read
        assert not tier.has(h) and tier.used() == 0

    def test_kind_mismatch_raises_and_consumes(self):
        tier = HostPageTier(2)
        h = tier.put([np.zeros(4, np.float32)], KIND_STATE)
        with pytest.raises(PageCorruptionError):
            tier.take(h, expect_kind=KIND_KV)
        assert not tier.has(h)

    def test_evict_lru_skips_pinned(self):
        tier = HostPageTier(3)
        pinned = tier.put([np.zeros(2, np.float32)], KIND_KV, pinned=True)
        old = tier.put([np.ones(2, np.float32)], KIND_KV)
        new = tier.put([np.full(2, 2.0, np.float32)], KIND_KV)
        ev = tier.evict_lru()
        assert ev is not None and ev[0] == old  # oldest UNPINNED entry
        assert tier.has(pinned) and tier.has(new)
        tier.pin(pinned, False)
        ev2 = tier.evict_lru()
        assert ev2 is not None and ev2[0] == pinned  # unpinned → evictable
        # only pinned entries left → eviction refuses
        tier.pin(new)
        assert tier.evict_lru() is None

    def test_capacity_is_a_hard_bound(self):
        tier = HostPageTier(1)
        tier.put([np.zeros(2, np.float32)], KIND_KV)
        assert tier.full()
        with pytest.raises(AssertionError):
            tier.put([np.zeros(2, np.float32)], KIND_KV)

    def test_snapshot_keys(self):
        tier = HostPageTier(2)
        tier.put([np.zeros(2, np.float32)], KIND_KV, pinned=True)
        snap = tier.snapshot()
        assert snap == {
            "used": 1, "capacity": 2,
            "bytes_resident": 8, "pinned": 1,
        }


# ------------------------------------------------------ bitwise movers
class TestPageMoversBitwise:
    def test_kv_page_round_trip_bitwise_across_dtypes(self):
        rng = np.random.default_rng(0)
        pool = {
            "f32": jnp.asarray(rng.normal(size=(2, 6, 4)).astype(np.float32)),
            "bf16": jnp.asarray(
                rng.normal(size=(2, 6, 4)).astype(np.float32)
            ).astype(jnp.bfloat16),
        }
        src = pages_lib.kv_page_fetch(pool, 3)
        want = [np.asarray(a).copy() for a in src]
        tier = HostPageTier(2)
        entry = tier.take(tier.put(src, KIND_KV))
        pool = pages_lib.kv_page_insert(pool, entry.arrays, 5)
        got = pages_lib.kv_page_fetch(pool, 5)
        for w, g in zip(want, got):
            assert w.dtype == g.dtype
            # bitwise, not allclose: the swap path must never requantize
            np.testing.assert_array_equal(
                w.view(np.uint8) if w.dtype == np.float32 else w, g.view(
                    np.uint8) if g.dtype == np.float32 else g)

    def test_state_page_round_trip_bitwise_with_replicated_leaf(self):
        rng = np.random.default_rng(1)
        spool = {
            "conv": jnp.asarray(rng.normal(size=(4, 3, 5)).astype(np.float32)),
            "ssm": jnp.asarray(rng.normal(size=(4, 2, 2)).astype(np.float32)),
            "step": jnp.asarray(np.int32(11)),  # pool-global, not per-page
        }
        axes = {"conv": 0, "ssm": 0, "step": pages_lib.REPLICATED}
        src = pages_lib.state_page_fetch(spool, axes, 1)
        assert len(src) == 2  # the replicated leaf does not travel
        want = [a.copy() for a in src]
        tier = HostPageTier(2)
        entry = tier.take(tier.put(src, KIND_STATE))
        spool = pages_lib.state_page_insert(spool, axes, entry.arrays, 2)
        got = pages_lib.state_page_fetch(spool, axes, 2)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g)
        assert int(spool["step"]) == 11

    def test_digest_is_order_and_content_sensitive(self):
        a = np.arange(8, dtype=np.float32)
        b = np.arange(8, dtype=np.float32) + 1
        assert pages_lib.page_digest([a, b]) != pages_lib.page_digest([b, a])
        assert pages_lib.page_digest([a]) != pages_lib.page_digest([b])
        assert pages_lib.page_digest([a]) == pages_lib.page_digest([a.copy()])


# ------------------------------------------------------ engine: prefix
class TestHostPrefixHits:
    def test_evicted_prefix_pages_serve_from_host_exactly(self):
        eng = _mk_engine()
        warm = _req(0, plen=16, max_new=1)
        eng.submit(warm)
        eng.run_to_completion(max_ticks=30)
        assert eng.prefix.reclaimable_count() > 0
        # demote every parked page to the host tier (the pressure path
        # runs this same eviction under a dry allocator)
        demoted = 0
        while eng._evict_parked_page() is not None:
            demoted += 1
        assert demoted > 0
        assert _swap(eng)["swap_outs"] == demoted
        assert eng.prefix.host_count() == demoted
        assert eng.prefix.reclaimable_count() == 0
        # the identical prompt hits host-resident chunks: streamed back
        # into fresh pids, output bit-identical
        hits_before = eng.stats["prefix_hits"]
        again = _req(0, plen=16, max_new=1)
        eng.submit(again)
        fin, _ = eng.run_to_completion(max_ticks=30)
        assert [r for r in fin if r is again][0].out == expected_greedy(
            again.prompt, 1)
        assert eng.stats["prefix_hits"] > hits_before
        sw = _swap(eng)
        assert sw["verified_swapins"] > 0 and sw["corrupt_swapins"] == 0
        assert sw["swap_ins"] == sw["verified_swapins"]
        eng.audit(strict=True)
        assert _no_referenced_pages(eng)

    def test_disabled_tier_evictions_discard(self):
        eng = _mk_engine(host_pages=0)
        assert eng.health()["host_tier"] is None
        warm = _req(0, plen=16, max_new=1)
        eng.submit(warm)
        eng.run_to_completion(max_ticks=30)
        while eng._evict_parked_page() is not None:
            pass
        assert eng.prefix.host_count() == 0
        assert all(v == 0 for v in _swap(eng).values())


# --------------------------------------------- engine: preempt → resume
class TestPreemptSwapResume:
    def test_preempted_decoder_resumes_from_host_exact(self):
        eng = _mk_engine()
        req = _req(0, plen=12, max_new=10)
        eng.submit(req)
        _step_until_decoding(eng, req)
        assert eng._preempt_one(None) is not None
        sw = _swap(eng)
        assert sw["swap_outs"] > 0  # pages snapshotted, pinned
        assert eng.health()["host_tier"]["pinned"] == sw["swap_outs"]
        eng.audit(strict=True)  # pinned carries are audit-clean mid-queue
        prefill_before = eng.stats["prefill_launches"]
        fin, _ = eng.run_to_completion(max_ticks=40)
        assert fin[0].rid == 0 and fin[0].error is None
        assert fin[0].out == expected_greedy(req.prompt, 10)
        # the resume streamed pages back and rejoined decode: no second
        # prefill pass
        assert eng.stats["prefill_launches"] == prefill_before
        sw = _swap(eng)
        assert sw["verified_swapins"] == sw["swap_outs"]
        assert sw["swap_ins"] == sw["verified_swapins"] + sw["corrupt_swapins"]
        assert eng.health()["host_tier"]["pinned"] == 0
        eng.audit(strict=True)
        assert _no_referenced_pages(eng)

    def test_double_preemption_folds_output_once(self):
        # regression for _orig_plen: the second requeue must append only
        # the output suffix the first requeue did not already fold in
        eng = _mk_engine()
        req = _req(0, plen=12, max_new=10)
        eng.submit(req)
        n1 = _step_until_decoding(eng, req)
        assert eng._preempt_one(None) is not None
        _step_until_decoding(eng, req, min_out=n1 + 2)
        assert eng._preempt_one(None) is not None
        fin, _ = eng.run_to_completion(max_ticks=60)
        assert fin[0].error is None
        assert fin[0].out == expected_greedy(req.prompt, 10)
        assert eng.stats["preemptions"] == 2
        eng.audit(strict=True)
        assert _no_referenced_pages(eng)

    def test_disabled_tier_preemption_is_pure_recompute(self):
        eng = _mk_engine(host_pages=0)
        req = _req(0, plen=12, max_new=10)
        eng.submit(req)
        _step_until_decoding(eng, req)
        assert eng._preempt_one(None) is not None
        fin, _ = eng.run_to_completion(max_ticks=40)
        assert fin[0].error is None
        assert fin[0].out == expected_greedy(req.prompt, 10)
        assert all(v == 0 for v in _swap(eng).values())

    def test_tier_too_small_for_carry_skips_to_recompute(self):
        # a 1-entry tier cannot hold a multi-page carry: the swap-out is
        # refused (counted as a skip) and recompute still lands exact
        eng = _mk_engine(host_pages=1)
        req = _req(0, plen=12, max_new=10)
        eng.submit(req)
        _step_until_decoding(eng, req)
        assert eng._preempt_one(None) is not None
        assert _swap(eng)["swap_outs"] == 0
        assert _swap(eng)["swap_skips"] >= 1
        fin, _ = eng.run_to_completion(max_ticks=40)
        assert fin[0].error is None
        assert fin[0].out == expected_greedy(req.prompt, 10)
        assert _no_referenced_pages(eng)


# ---------------------------------------------------- engine: fault seams
class TestSwapFaultSeams:
    def test_swap_out_fault_falls_back_to_recompute_exact(self):
        faults = FaultInjector(seed=0, rates={"swap_out": 1.0})
        eng = _mk_engine(faults)
        req = _req(0, plen=12, max_new=10)
        eng.submit(req)
        _step_until_decoding(eng, req)
        assert eng._preempt_one(None) is not None
        assert _swap(eng)["swap_outs"] == 0
        assert _swap(eng)["swap_skips"] >= 1
        fin, _ = eng.run_to_completion(max_ticks=40)
        assert fin[0].error is None
        assert fin[0].out == expected_greedy(req.prompt, 10)
        assert eng.health()["host_tier"]["used"] == 0
        eng.audit(strict=True)

    def test_swap_in_fault_drops_carry_and_recomputes_exact(self):
        faults = FaultInjector(seed=0, rates={"swap_in": 1.0})
        eng = _mk_engine(faults)
        req = _req(0, plen=12, max_new=10)
        eng.submit(req)
        _step_until_decoding(eng, req)
        assert eng._preempt_one(None) is not None
        assert _swap(eng)["swap_outs"] > 0  # the carry WAS made
        fin, _ = eng.run_to_completion(max_ticks=40)
        assert fin[0].error is None
        assert fin[0].out == expected_greedy(req.prompt, 10)
        # every swap-in refused: no page ever streamed back, the carried
        # handles were dropped (tier fully drained, nothing pinned)
        assert _swap(eng)["swap_ins"] == 0
        assert eng.health()["host_tier"]["used"] == 0
        eng.audit(strict=True)
        assert _no_referenced_pages(eng)

    def test_corrupt_swap_in_quarantines_only_the_owner(self):
        faults = FaultInjector(seed=0, rates={"swap_corrupt": 1.0})
        eng = _mk_engine(faults)
        victim = _req(0, plen=12, max_new=10)
        bystander = _req(1, plen=12, max_new=10)
        eng.submit(victim)
        eng.submit(bystander)
        _step_until_decoding(eng, victim)
        # preempt the youngest (the bystander would be victim #1, so pick
        # explicitly: preempt whichever slot holds rid 0)
        idx = next(i for i, s in enumerate(eng.slots)
                   if s.req is not None and s.req.rid == 0)
        other = 0 if idx != 0 else 1
        assert eng._preempt_one(exclude=other) is not None
        fin, _ = eng.run_to_completion(max_ticks=60)
        by_rid = {r.rid: r for r in fin}
        bad = [r for r in fin if r.error is not None]
        assert len(bad) == 1 and bad[0].error.kind == "quarantined"
        assert "integrity" in str(bad[0].error)
        ok = by_rid[bystander.rid]
        assert ok.error is None
        assert ok.out == expected_greedy(bystander.prompt, 10)
        sw = _swap(eng)
        assert sw["corrupt_swapins"] >= 1
        assert sw["swap_ins"] == sw["verified_swapins"] + sw["corrupt_swapins"]
        assert eng.health()["host_tier"]["used"] == 0
        eng.audit(strict=True)
        assert _no_referenced_pages(eng)


# ------------------------------------------------- recompression ladder
class TestRecompressionLadder:
    def _warm(self, eng):
        warm = _req(0, plen=16, max_new=1)
        eng.submit(warm)
        eng.run_to_completion(max_ticks=30)
        assert eng.prefix.reclaimable_count() > 0
        return warm

    def _force_pressure(self, eng, rounds=1):
        # pin the pressure signal low so _recompress_tick fires without
        # actually exhausting the pool (which would leak references)
        orig = eng._available_pages
        eng._available_pages = lambda: 0
        try:
            for _ in range(rounds):
                eng._recompress_tick(budget=8)
        finally:
            eng._available_pages = orig

    def test_int8_stage_is_exact_for_integer_payloads(self):
        eng = _mk_engine(recompress_after=1)
        self._warm(eng)
        self._force_pressure(eng)
        assert _swap(eng)["recompressed_pages"] > 0
        assert set(eng._recompress_stage.values()) == {1}  # int8
        again = _req(0, plen=16, max_new=1)
        eng.submit(again)
        fin, _ = eng.run_to_completion(max_ticks=30)
        hit = [r for r in fin if r is again][0]
        # the stub cache stores token values < VOCAB=32 <= 127: the int8
        # stage round-trips them exactly
        assert hit.error is None
        assert hit.out == expected_greedy(again.prompt, 1)
        eng.audit(strict=True)

    def test_bcq4_stage_stays_contained(self):
        # 4-bit value precision IS lossy for the stub's payloads — the
        # contract at this stage is tolerance-tier math with fully intact
        # bookkeeping, not exactness
        eng = _mk_engine(recompress_after=1)
        self._warm(eng)
        self._force_pressure(eng, rounds=2)
        assert max(eng._recompress_stage.values()) == 2  # bcq4
        again = _req(0, plen=16, max_new=1)
        eng.submit(again)
        fin, _ = eng.run_to_completion(max_ticks=30)
        assert [r for r in fin if r is again][0].error is None
        eng.audit(strict=True)
        assert _no_referenced_pages(eng)

    def test_stage_marker_travels_through_the_host_tier(self):
        eng = _mk_engine(recompress_after=1)
        self._warm(eng)
        self._force_pressure(eng)
        staged = set(eng._recompress_stage)
        assert staged
        while eng._evict_parked_page() is not None:
            pass
        # demoted pages left HBM: their stage markers went with them
        assert not (staged & set(eng._recompress_stage))
        again = _req(0, plen=16, max_new=1)
        eng.submit(again)
        fin, _ = eng.run_to_completion(max_ticks=30)
        hit = [r for r in fin if r is again][0]
        assert hit.error is None
        assert hit.out == expected_greedy(again.prompt, 1)  # int8: exact
        assert _swap(eng)["verified_swapins"] > 0
        # the swapped-in pids re-acquired their int8 stage from entry meta
        assert 1 in eng._recompress_stage.values()
        eng.audit(strict=True)

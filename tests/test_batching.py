"""Continuous batching == sequential single-request serving (greedy)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke
from repro.launch.batching import ContinuousBatcher, Request
from repro.models import zoo
from repro.models.layers import Runtime

RT = Runtime(quant_mode="none", compute_dtype=jnp.float32, param_dtype=jnp.float32)


def _sequential_reference(api, params, prompt, n_new, max_len):
    tokens = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, caches = api.prefill_fn(params, {"tokens": tokens}, max_len)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new):
        logits, caches = api.decode_fn(
            params, caches, jnp.asarray([[out[-1]]], jnp.int32), jnp.int32(pos)
        )
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out


def test_continuous_batching_matches_sequential():
    cfg = get_smoke("gpt3_126m")
    api = zoo.build(cfg, RT)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32) for n in (5, 9, 7, 6)]
    n_new = 4
    max_len = 32

    refs = [_sequential_reference(api, params, p, n_new, max_len) for p in prompts]

    cb = ContinuousBatcher(api, params, n_slots=2, max_len=max_len)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new=n_new))
    finished, ticks = cb.run_to_completion()
    assert len(finished) == 4
    got = {r.rid: r.out for r in finished}
    for i, ref in enumerate(refs):
        assert got[i][: n_new + 1] == ref[: n_new + 1], (i, got[i], ref)
    # with 2 slots and 4 requests, batching must have overlapped work
    assert ticks < sum(n_new + 1 for _ in prompts)

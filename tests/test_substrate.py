"""Substrate tests: data pipeline, optimizer, checkpointing, elastic
runtime, gradient compression."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, Prefetcher, batch_at
from repro.optim import adamw
from repro.optim.compress import _dequantize, _quantize_int8
from repro.runtime.elastic import Watchdog, derive_mesh


# ------------------------------------------------------------------- data
def test_data_deterministic_and_sharded():
    g = DataConfig(vocab=1000, seq_len=32, global_batch=8)
    b1, b2 = batch_at(g, 5), batch_at(g, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(batch_at(g, 6)["tokens"], b1["tokens"])
    # host-sharded == slices of the global batch (elasticity invariant)
    h0 = DataConfig(vocab=1000, seq_len=32, global_batch=8, n_hosts=2, host_id=0)
    h1 = DataConfig(vocab=1000, seq_len=32, global_batch=8, n_hosts=2, host_id=1)
    got = np.concatenate([batch_at(h0, 5)["tokens"], batch_at(h1, 5)["tokens"]])
    np.testing.assert_array_equal(got, b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"])[:, 1:], np.asarray(b1["labels"])[:, :-1])


def test_data_learnable_structure():
    """Bigram structure exists: successor entropy < unconditional entropy."""
    g = DataConfig(vocab=64, seq_len=512, global_batch=4)
    t = np.asarray(batch_at(g, 0)["tokens"]).ravel()
    pairs = {}
    for a, b in zip(t[:-1], t[1:]):
        pairs.setdefault(int(a), []).append(int(b))
    top = max(pairs, key=lambda k: len(pairs[k]))
    succ = np.array(pairs[top])
    _, counts = np.unique(succ, return_counts=True)
    top4 = np.sort(counts)[::-1][:4].sum() / len(succ)
    assert top4 > 0.5  # ~75% of successors come from 4 preferred tokens


def test_prefetcher():
    g = DataConfig(vocab=100, seq_len=16, global_batch=2)
    pf = Prefetcher(g, start_step=3)
    it = iter(pf)
    s0, b0 = next(it)
    s1, _ = next(it)
    pf.close()
    assert (s0, s1) == (3, 4)
    np.testing.assert_array_equal(b0["tokens"], batch_at(g, 3)["tokens"])


# -------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    p = {"w": {"kernel": jnp.array([[3.0, -2.0]])}}
    st = adamw.init_state(p)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
    for _ in range(60):
        g = jax.tree.map(lambda x: 2 * x, p)
        p, st, m = adamw.apply_updates(p, g, st, cfg)
    assert float(jnp.abs(p["w"]["kernel"]).max()) < 0.5
    assert int(st["step"]) == 60


def test_adamw_skips_integer_leaves():
    p = {"w": {"kernel": jnp.ones((4, 4))}, "packed": jnp.ones((4,), jnp.uint8)}
    st = adamw.init_state(p)
    g = {"w": {"kernel": jnp.ones((4, 4))}, "packed": jnp.zeros((4,), jnp.uint8)}
    p2, _, _ = adamw.apply_updates(p, g, st, adamw.AdamWConfig())
    np.testing.assert_array_equal(p2["packed"], p["packed"])
    assert not np.array_equal(p2["w"]["kernel"], p["w"]["kernel"])


def test_clip_norm():
    p = {"w": jnp.zeros((10,))}
    st = adamw.init_state(p)
    g = {"w": jnp.full((10,), 100.0)}
    _, _, m = adamw.apply_updates(p, g, st, adamw.AdamWConfig(clip_norm=1.0))
    assert float(m["grad_norm"]) > 100


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    cm = ckpt.CheckpointManager(str(tmp_path), keep=2)
    tree = {
        "a": jnp.arange(6).reshape(2, 3),
        "b": {"c": jnp.float32(1.5), "d": [jnp.ones((2,)), jnp.zeros((3,), jnp.int8)]},
    }
    cm.save(1, tree, blocking=True)
    step, back = cm.restore()
    assert step == 1
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"]["d"][1], tree["b"]["d"][1])
    assert back["b"]["d"][1].dtype == np.int8


def test_checkpoint_retention_and_async(tmp_path):
    cm = ckpt.CheckpointManager(str(tmp_path), keep=2)
    for s in range(1, 6):
        cm.save(s, {"x": jnp.full((4,), s)}, blocking=True)
    steps = cm.all_steps()
    assert steps == [4, 5]
    cm.save(6, {"x": jnp.full((4,), 6.0)})  # async
    deadline = time.time() + 5
    while cm.latest_step() != 6 and time.time() < deadline:
        time.sleep(0.05)
    assert cm.latest_step() == 6
    _, t = cm.restore(6)
    np.testing.assert_array_equal(t["x"], np.full((4,), 6.0))


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp leftover never shadows a committed checkpoint."""
    cm = ckpt.CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, {"x": jnp.ones((2,))}, blocking=True)
    # simulate a crashed write
    open(os.path.join(str(tmp_path), "step_00000002.npz.tmp.npz"), "wb").write(b"garbage")
    assert cm.latest_step() == 1
    _, t = cm.restore()
    np.testing.assert_array_equal(t["x"], np.ones((2,)))


# ---------------------------------------------------------------- elastic
def test_derive_mesh_single_device():
    m = derive_mesh(model_parallel=16)
    assert m.devices.size == len(jax.devices())
    assert m.axis_names == ("data", "model")


def test_watchdog_straggler_detection():
    w = Watchdog(n_hosts=4)
    t = 0.0
    for step in range(5):
        for h in range(4):
            dt = 1.0 if h != 2 else 5.0  # host 2 is 5× slower
            w.beat(h, step, t=step * 1.0 + (dt if step else 0) * 0)
    # feed real per-host cadences
    w2 = Watchdog(n_hosts=3)
    for step in range(4):
        w2.beat(0, step, t=step * 1.0)
        w2.beat(1, step, t=step * 1.1)
        w2.beat(2, step, t=step * 9.0)
    assert w2.stragglers() == [2]
    assert w2.missing(timeout=5.0, now=40.0) == [0, 1, 2]


# ------------------------------------------------------------ compression
def test_int8_error_feedback_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(0), (5000,)) * 3
    q, s, n = _quantize_int8(x)
    back = _dequantize(q, s, n)
    assert float(jnp.max(jnp.abs(back - x))) < float(jnp.max(jnp.abs(x))) / 100
    # error feedback: accumulated residual keeps the SUM of updates faithful
    err = jnp.zeros_like(x)
    total_sent = jnp.zeros_like(x)
    for _ in range(8):
        carry = x + err
        q, s, n = _quantize_int8(carry)
        sent = _dequantize(q, s, n)
        err = carry - sent
        total_sent += sent
    np.testing.assert_allclose(np.asarray(total_sent / 8), np.asarray(x), atol=0.02)

"""Chunked prefill: Pallas kernel == oracle (interpret mode) across page
kinds / chunk sizes / ragged prefix lengths, and PagedEngine chunked
admission token-for-token identical to full-prompt prefill for every cache
kind and prefix-hit fraction (0%, partial, 100%), including mixed
prefill/decode ticks and prompts longer than max_len."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke
from repro.core.bcq import BCQConfig
from repro.core.calibrate import default_universal_codebooks
from repro.kernels import ref as kref
from repro.kernels.chunked_prefill import chunked_prefill
from repro.models import layers, zoo
from repro.models.layers import Runtime
from repro.serving.engine import PagedEngine
from repro.serving.generate import Request

CFG = get_smoke("gpt3_126m")
BCQ = BCQConfig()
CB = default_universal_codebooks(BCQ).as_jnp()
MAX_LEN, PS = 32, 8
P, HKV, D = 8, 2, 32  # kernel-test pool shape


# ------------------------------------------------------------ kernel == ref
def _pool(kind, key=0):
    pool = layers.cache_init(P, PS, HKV, D, kind, BCQ)
    k = jax.random.normal(jax.random.PRNGKey(key), (P, PS, HKV, D))
    v = jax.random.normal(jax.random.PRNGKey(key + 1), (P, PS, HKV, D))
    return layers.cache_write(pool, k, v, 0, kind, BCQ, CB)


@pytest.mark.parametrize("kind", ("bf16", "int8", "bcq4"))
@pytest.mark.parametrize("h", (2, 4))  # MHA and 2× GQA replication
def test_kernel_matches_reference(kind, h):
    """Ragged hit-chain lengths (n_past 0 / mid-page-multiple / deep) and
    several chunk sizes, one pool per kind."""
    pool = _pool(kind)
    rng = np.random.default_rng(0)
    for c in (1, 5, 8):  # decode-like, ragged tail, full-page chunk
        b, maxp = 3, 4
        bt = jnp.asarray(rng.integers(1, P, (b, maxp)), jnp.int32)
        # chunk starts page-aligned in the engine, but the kernel only
        # needs n_past + C to fit the gathered pages — exercise both
        n_past = jnp.asarray([0, PS, (maxp - 1) * PS - c], jnp.int32)
        q = jax.random.normal(jax.random.PRNGKey(7 + c), (b, c, h, D))
        ref = kref.chunked_prefill_ref(q, pool, bt, n_past, kind, BCQ, CB)
        got = chunked_prefill(q, pool, bt, n_past, kind, BCQ, CB, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5
        )


def test_kernel_causal_within_chunk():
    """Chunk token c must not see chunk tokens > c: corrupting the page
    region holding later chunk tokens leaves earlier rows unchanged."""
    pool = _pool("bf16")
    bt = jnp.asarray([[1, 2, 0]], jnp.int32)
    n_past = jnp.asarray([PS], jnp.int32)  # chunk occupies page 2 onward
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 4, HKV, D))
    out_a = chunked_prefill(q, pool, bt, n_past, "bf16", BCQ, interpret=True)
    pool2 = dict(pool)
    pool2["k"] = pool["k"].at[2, 2:].set(777.0)  # tokens at positions >= n_past+2
    pool2["v"] = pool["v"].at[2, 2:].set(777.0)
    out_b = chunked_prefill(q, pool2, bt, n_past, "bf16", BCQ, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_a[:, :2]), np.asarray(out_b[:, :2]))
    assert not np.array_equal(np.asarray(out_a[:, 2:]), np.asarray(out_b[:, 2:]))


def test_kernel_prefix_pages_visible_to_whole_chunk():
    """All prefix tokens (positions < n_past) influence every chunk row."""
    pool = _pool("bf16")
    bt = jnp.asarray([[3, 1, 0]], jnp.int32)
    n_past = jnp.asarray([PS], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(4), (1, 3, HKV, D))
    out_a = chunked_prefill(q, pool, bt, n_past, "bf16", BCQ, interpret=True)
    pool2 = dict(pool)
    pool2["k"] = pool["k"].at[3, PS - 1].set(9.0)  # last prefix token
    out_b = chunked_prefill(q, pool2, bt, n_past, "bf16", BCQ, interpret=True)
    assert not np.array_equal(np.asarray(out_a), np.asarray(out_b))


# --------------------------------------------------- model chunk attention
def test_model_kernel_path_matches_jnp_path():
    """prefill_from_pages with Runtime.paged_kernel (Pallas chunked-prefill
    kernel, interpret on CPU) agrees with the jnp gather path."""
    outs = {}
    for paged_kernel in (False, True):
        rt = Runtime(
            quant_mode="none", compute_dtype=jnp.float32, param_dtype=jnp.float32,
            cache_kind="bcq4", paged_kernel=paged_kernel,
        )
        api = zoo.build(CFG, rt)
        params = api.init(jax.random.PRNGKey(0))
        params["codebooks"] = CB
        pool = api.pool_init(6, PS)
        tokens = jnp.asarray(np.arange(1, 6)[None, :], jnp.int32)
        bt = jnp.asarray([[1, 0, 0, 0]], jnp.int32)
        logits, _ = api.prefill_from_pages_fn(
            params, tokens, pool, bt, jnp.asarray([0], jnp.int32),
            jnp.asarray([[1]], jnp.int32),
        )
        outs[paged_kernel] = np.asarray(logits)
    np.testing.assert_allclose(outs[False], outs[True], atol=3e-5, rtol=3e-5)


# ------------------------------------------------------ engine equivalence
def _api_params(kind):
    rt = Runtime(
        quant_mode="none", compute_dtype=jnp.float32, param_dtype=jnp.float32,
        cache_kind=kind,
    )
    api = zoo.build(CFG, rt)
    params = api.init(jax.random.PRNGKey(0))
    params["codebooks"] = CB
    return api, params


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    finished, ticks = engine.run_to_completion()
    return {r.rid: list(r.out) for r in finished}, ticks


def _mix(rng):
    """0% / partial / would-be-100% prefix-hit prompts in one batch."""
    shared = rng.integers(0, CFG.vocab, size=PS).astype(np.int32)
    return [
        np.concatenate([shared, rng.integers(0, CFG.vocab, size=3).astype(np.int32)]),
        np.concatenate([shared, rng.integers(0, CFG.vocab, size=5).astype(np.int32)]),
        rng.integers(0, CFG.vocab, size=17).astype(np.int32),
    ]


@pytest.mark.parametrize("kind", ("bf16", "int8", "bcq4"))
def test_chunked_engine_matches_full_prefill(kind):
    """Cold pass (0% and partial hits) AND a warm 100%-hit resubmission are
    token-for-token identical to the full-prompt-prefill engine."""
    api, params = _api_params(kind)
    prompts = _mix(np.random.default_rng(0))

    ref_eng = PagedEngine(api, params, n_slots=2, max_len=MAX_LEN, page_size=PS)
    ref, _ = _run(ref_eng, [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)])
    ref_eng.submit(Request(rid=9, prompt=prompts[0].copy(), max_new=4))
    ref_eng.run_to_completion()
    ref[9] = list(next(r.out for r in ref_eng.finished if r.rid == 9))

    eng = PagedEngine(
        api, params, n_slots=2, max_len=MAX_LEN, page_size=PS,
        chunked_prefill=True, prefill_chunk=PS,
    )
    got, _ = _run(eng, [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)])
    cold_tokens = eng.stats["prefill_tokens"]

    # warm resubmission: every full page of prompts[0] is now cached — the
    # engine must run prefill over ONLY the final partial page (zero
    # attention FLOPs over the cached pages) and still match exactly
    eng.submit(Request(rid=9, prompt=prompts[0].copy(), max_new=4))
    eng.run_to_completion()
    got[9] = list(next(r.out for r in eng.finished if r.rid == 9))
    plen = len(prompts[0])
    suffix = plen - (plen - 1) // PS * PS
    assert eng.stats["prefix_hits"] >= (plen - 1) // PS
    assert eng.stats["prefill_tokens"] - cold_tokens == suffix
    assert got == ref, (kind, got, ref)


def test_chunked_engine_chunk_size_invariance():
    """Greedy outputs are identical for any page-multiple chunk size."""
    api, params = _api_params("bf16")
    prompts = _mix(np.random.default_rng(1))
    outs = []
    for chunk in (PS, 2 * PS, 3 * PS):
        eng = PagedEngine(
            api, params, n_slots=2, max_len=MAX_LEN, page_size=PS,
            chunked_prefill=True, prefill_chunk=chunk,
        )
        got, _ = _run(eng, [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)])
        outs.append(got)
    assert outs[0] == outs[1] == outs[2]


def test_mixed_prefill_decode_ticks():
    """While one slot prefills chunk-by-chunk, another keeps decoding — and
    outputs still match the non-chunked engine exactly."""
    api, params = _api_params("bf16")
    rng = np.random.default_rng(2)
    short = rng.integers(0, CFG.vocab, size=4).astype(np.int32)
    long = rng.integers(0, CFG.vocab, size=24).astype(np.int32)

    ref, _ = _run(
        PagedEngine(api, params, n_slots=2, max_len=MAX_LEN, page_size=PS),
        [Request(rid=0, prompt=short, max_new=6), Request(rid=1, prompt=long, max_new=3)],
    )
    eng = PagedEngine(
        api, params, n_slots=2, max_len=MAX_LEN, page_size=PS,
        chunked_prefill=True, prefill_chunk=PS,
    )
    got, _ = _run(
        eng,
        [Request(rid=0, prompt=short, max_new=6), Request(rid=1, prompt=long, max_new=3)],
    )
    # the long prompt needed 3 chunks; decode ticks for the short request
    # ran in the same window (interleaved, not serialized behind prefill)
    assert eng.stats["prefill_chunks"] >= 3 + 1
    assert eng.stats["decode_ticks"] > 0
    assert got == ref


def test_one_chunk_launch_per_engine_tick():
    """ALL prefilling slots ride ONE prefill_from_pages launch per tick —
    the launch count equals the number of prefill ticks, never the number
    of (slot, chunk) pairs — with token-for-token equivalence to the
    non-chunked reference engine preserved."""
    api, params = _api_params("bf16")
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(0, CFG.vocab, size=n).astype(np.int32) for n in (24, 20, 17)
    ]
    ref, _ = _run(
        PagedEngine(api, params, n_slots=3, max_len=MAX_LEN, page_size=PS),
        [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)],
    )

    eng = PagedEngine(
        api, params, n_slots=3, max_len=MAX_LEN, page_size=PS,
        chunked_prefill=True, prefill_chunk=PS,
    )
    calls = [0]
    inner = eng._chunk_step

    def counting(*args):
        calls[0] += 1
        return inner(*args)

    eng._chunk_step = counting
    got, _ = _run(eng, [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)])
    assert got == ref
    # 3 prompts × 3 chunks each = 9 chunks, but 3 slots prefill together:
    # one launch per tick, so far fewer launches than chunks
    assert calls[0] == eng.stats["prefill_launches"]
    assert eng.stats["prefill_chunks"] == 9
    assert calls[0] <= 4, (calls[0], eng.stats)


def test_retrace_count_bounded_by_buckets_not_requests():
    """Shape-bucketing regression: a mixed-length serving run traces each
    device step a BOUNDED (bucket-count) number of times — and a second
    wave of fresh lengths through the warmed engine adds ZERO traces
    (steady state stops retracing).  Before bucketing, every distinct
    tail-chunk length and every admission mix recompiled the chunk step:
    traces grew O(requests)."""
    api, params = _api_params("bf16")
    rng = np.random.default_rng(6)
    eng = PagedEngine(
        api, params, n_slots=2, max_len=MAX_LEN, page_size=PS, n_pages=40,
        chunked_prefill=True, prefill_chunk=2 * PS,
    )
    lengths_cold = (3, 5, 7, 9, 11, 14, 17, 19, 22, 25)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, CFG.vocab, size=n).astype(np.int32),
                max_new=3)
        for i, n in enumerate(lengths_cold)
    ]
    _run(eng, reqs)
    cold = eng.trace_counts()
    # buckets: tail chunks round to pow2 (≤ log2(prefill_chunk)+1 token
    # shapes), prefill batch pads to pow2 (≤ log2(n_slots)+1), decode is
    # one fixed shape — an order of magnitude under one-per-request
    assert 0 < cold["chunk"] <= 8, cold
    assert cold["decode"] == 1, cold

    # second wave: same length mix, FRESH tokens (zero prefix hits, so the
    # prefill really runs again) — all shapes land in warmed buckets
    wave2 = [
        Request(rid=100 + i, prompt=rng.integers(0, CFG.vocab, size=n).astype(np.int32),
                max_new=3)
        for i, n in enumerate(lengths_cold)
    ]
    _run(eng, wave2)
    warm = eng.trace_counts()
    assert warm == cold, (cold, warm)  # steady state: zero new compilations

    # a second engine over the same api starts fully warm (shared jit
    # cache): the whole workload replays without a single compilation
    eng2 = PagedEngine(
        api, params, n_slots=2, max_len=MAX_LEN, page_size=PS, n_pages=40,
        chunked_prefill=True, prefill_chunk=2 * PS,
    )
    wave3 = [
        Request(rid=200 + i, prompt=rng.integers(0, CFG.vocab, size=n).astype(np.int32),
                max_new=3)
        for i, n in enumerate(lengths_cold)
    ]
    _run(eng2, wave3)
    assert sum(eng2.trace_counts().values()) == 0, eng2.trace_counts()


def test_chunked_lifts_prompt_length_limit():
    """A prompt LONGER than max_len serves through chunked admission (block
    tables grow page-by-page) and matches a big-slab reference engine."""
    api, params = _api_params("int8")
    rng = np.random.default_rng(3)
    long = rng.integers(0, CFG.vocab, size=MAX_LEN + 9).astype(np.int32)

    eng = PagedEngine(
        api, params, n_slots=1, max_len=MAX_LEN, page_size=PS, n_pages=16,
        chunked_prefill=True, prefill_chunk=2 * PS,
    )
    got, _ = _run(eng, [Request(rid=0, prompt=long, max_new=3)])
    assert eng.tables.shape[1] * PS > MAX_LEN  # tables actually grew

    big = PagedEngine(api, params, n_slots=1, max_len=2 * MAX_LEN, page_size=PS, n_pages=16)
    ref, _ = _run(big, [Request(rid=0, prompt=long, max_new=3)])
    assert got == ref


@pytest.mark.parametrize("kind", ("bf16", "int8", "bcq4"))
def test_double_buffered_dma_bitwise_identical_chunked(kind):
    """double_buffer=True (two-slot async page copies) == the BlockSpec
    auto-pipeline, bitwise, for chunk-shaped queries over a paged prefix."""
    pool = _pool(kind)
    rng = np.random.default_rng(4)
    bt = jnp.asarray(rng.integers(1, P, (3, 4)), jnp.int32)
    n_past = jnp.asarray([0, PS, 2 * PS], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(5), (3, 8, 4, D))
    auto = chunked_prefill(
        q, pool, bt, n_past, kind, BCQ, CB, interpret=True,
        double_buffer=False,
    )
    manual = chunked_prefill(
        q, pool, bt, n_past, kind, BCQ, CB, interpret=True,
        double_buffer=True,
    )
    np.testing.assert_array_equal(np.asarray(manual), np.asarray(auto))

"""Unit tests for the zoo sharding rules (no devices needed — pure specs)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeConfig, get_arch, get_smoke
from repro.models import zoo
from repro.models.layers import Runtime

AXES = {"data": 16, "model": 16}
RT = Runtime(quant_mode="none")


def _specs(arch_id):
    cfg = get_arch(arch_id)
    api = zoo.build(cfg, RT)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    return cfg, shapes, zoo.param_pspecs(shapes, AXES)


def _leaves_with_specs(shapes, specs):
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    return list(zip(flat_s, flat_p))


@pytest.mark.parametrize("arch_id", ["qwen1_5_32b", "qwen3_moe_235b", "mamba2_130m", "recurrentgemma_9b"])
def test_specs_divisible(arch_id):
    """Every sharded dim divides its axis size — the compile-time contract."""
    _, shapes, specs = _specs(arch_id)
    for shp, spec in _leaves_with_specs(shapes, specs):
        for dim, names in zip(shp.shape, tuple(spec) + (None,) * 8):
            if names is None:
                continue
            ns = names if isinstance(names, tuple) else (names,)
            prod = 1
            for n in ns:
                prod *= AXES[n]
            assert dim % prod == 0, (arch_id, shp.shape, spec)


def test_fsdp_vs_tp_layout():
    """TP layout never shards over 'data' (no FSDP weight gathers)."""
    old = zoo.PARAM_LAYOUT
    try:
        zoo.PARAM_LAYOUT = "tp"
        _, shapes, specs = _specs("qwen1_5_32b")
        for shp, spec in _leaves_with_specs(shapes, specs):
            for names in tuple(spec):
                ns = names if isinstance(names, tuple) else (names,)
                assert "data" not in ns, (shp.shape, spec)
    finally:
        zoo.PARAM_LAYOUT = old


def test_large_params_are_sharded():
    """No ≥64 MiB leaf is left fully replicated under the training layout."""
    _, shapes, specs = _specs("qwen1_5_32b")
    for shp, spec in _leaves_with_specs(shapes, specs):
        n_bytes = shp.size * shp.dtype.itemsize
        if n_bytes >= 64 * 2**20:
            assert any(d is not None for d in tuple(spec)), (shp.shape, spec)


def test_cache_specs_shard_big_dims():
    cfg = get_arch("qwen1_5_32b")
    rt = Runtime(quant_mode="fake", compute_dtype=jnp.bfloat16)
    shape = ShapeConfig("d", "decode", 32768, 128)
    cs = zoo.cache_specs(cfg, rt, shape)
    specs = zoo.cache_pspecs(cs, AXES)
    k_spec = specs["k"]
    # (L, B, S, H=40, D): batch over data; 40 heads don't divide 16 → the
    # sequence dim takes 'model'
    assert tuple(k_spec) == (None, "data", "model", None, None), k_spec


def test_moe_expert_spec_variants():
    old = zoo.MOE_EXPERT_SPEC
    try:
        _, shapes, specs = _specs("qwen3_moe_235b")
        wi = specs["layers"]["moe"]["wi"]["kernel"]
        assert tuple(wi) == (None, "model", "data", None)
        zoo.MOE_EXPERT_SPEC = "tp2d"
        _, _, specs2 = _specs("qwen3_moe_235b")
        wi2 = specs2["layers"]["moe"]["wi"]["kernel"]
        wo2 = specs2["layers"]["moe"]["wo"]["kernel"]
        assert tuple(wi2) == (None, "model", None, "data")
        assert tuple(wo2) == (None, "model", "data", None)
    finally:
        zoo.MOE_EXPERT_SPEC = old


def test_batch_specs_multipod():
    axes = {"pod": 2, "data": 16, "model": 16}
    cfg = get_arch("qwen1_5_32b")
    rt = Runtime()
    specs = zoo.input_specs(cfg, rt, ShapeConfig("t", "train", 4096, 256))
    bs = zoo.batch_pspecs(specs, axes)
    assert bs["tokens"] == P(("pod", "data"), None)

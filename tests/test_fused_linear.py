"""Fused single-launch W4A4 linear (kernels/bcq_linear.py) validation.

Contracts:
* the Pallas fused kernel (interpret mode) is BIT-exact with the existing
  two-launch quantize→matmul Pallas path at matching tile sizes,
* both agree with the pure-jnp oracle ``ref.fused_linear_ref``,
* the qdense packed path is token-for-token identical through
  ``greedy_generate`` whether linears run fused or via the in-graph
  decode_packed_weight + einsum,
* ``interpret=None`` auto-detects the backend (no silent interpret mode on
  a real TPU; interpret everywhere else).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcq, ptq
from repro.core.bcq import BCQConfig
from repro.kernels import ops, ref
from repro.kernels.bcq_linear import bcq_linear_pallas
from repro.kernels.bcq_quantize import bcq_quantize_pallas

CFGS = [
    BCQConfig(block_len=4, array_len=32, n_codebooks=4),
    BCQConfig(),  # paper default g64 / L_b 8 / N_c 8
    BCQConfig(block_len=8, array_len=64, n_codebooks=16),
]


def _codebooks(cfg, seed=0):
    data = jax.random.laplace(jax.random.PRNGKey(seed), (60000,))
    return bcq.fit_lobcq(data, cfg, iters=4, max_blocks=4096).as_jnp()


def _two_launch(x, pw, cb, cfg, tiles):
    tm, tn, tk = tiles
    a = ops.quantize(x, cb, cfg, impl="pallas", tile_m=tm, tile_k=tk)
    return ops.matmul(a, pw, cb, cfg, impl="pallas", tile_m=tm, tile_n=tn, tile_k=tk)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag())
@pytest.mark.parametrize("tiles", [(64, 64, 256), (32, 64, 128), (128, 128, 512)])
def test_fused_bitexact_with_two_launch(cfg, tiles):
    """Acceptance: w4a4_linear_fused ≡ quantize∘matmul, bit for bit."""
    if tiles[2] % cfg.array_len:
        pytest.skip("tile_k must be a multiple of L_A")
    m, n, k = 128, 192, 512
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    w = jax.random.t(jax.random.PRNGKey(2), 3.0, (n, k))
    cb = _codebooks(cfg)
    tm, tn, tk = tiles
    pw = ops.quantize(w, cb, cfg, impl="pallas", tile_m=tn, tile_k=tk)
    o_fused = ops.w4a4_linear_fused(
        x, pw, cb, cfg, impl="pallas", tile_m=tm, tile_n=tn, tile_k=tk
    )
    o_two = _two_launch(x, pw, cb, cfg, tiles)
    np.testing.assert_array_equal(np.asarray(o_fused), np.asarray(o_two))


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.tag())
def test_fused_matches_ref_oracle(cfg):
    """Multi-K-tile shape (exercises the decoded-weight VMEM cache) vs the
    jnp oracle and the fake-quant expectation."""
    m, n, k = 96, 130, 4 * 256  # ragged rows/cols, 4 K tiles
    x = jax.random.normal(jax.random.PRNGKey(3), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(4), (n, k)) * 0.1
    cb = _codebooks(cfg)
    pw = ops.quantize(w, cb, cfg, impl="ref")
    o_ref = ops.w4a4_linear_fused(x, pw, cb, cfg, impl="ref")
    o_pl = ops.w4a4_linear_fused(
        x, pw, cb, cfg, impl="pallas", tile_m=64, tile_n=64, tile_k=256
    )
    assert o_pl.shape == (m, n)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref), rtol=1e-5, atol=1e-4)
    expect = bcq.fake_quant(x, cb, cfg) @ bcq.fake_quant(w, cb, cfg).T
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(expect), rtol=1e-4, atol=1e-3)


def test_fused_ref_equals_two_launch_ref():
    """The CPU fallback composes quantize_ref+matmul_ref — identical to the
    two-launch ref path (so the packed model path changes no ref numerics)."""
    cfg = BCQConfig()
    cb = _codebooks(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 40, 256), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(6), (96, 256))
    pw = ops.quantize(w, cb, cfg, impl="ref")
    o_fused = ops.w4a4_linear_fused(x, pw, cb, cfg, impl="ref")
    o_two = ops.w4a4_linear(x, pw, cb, cfg, impl="ref")
    assert o_fused.shape == (3, 40, 96) and o_fused.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(o_fused), np.asarray(o_two))


def test_fused_ragged_shapes_pad_correctly():
    cfg = BCQConfig(block_len=4, array_len=32, n_codebooks=4)
    cb = _codebooks(cfg)
    m, n, k = 100, 70, 320  # none tile-aligned (K still % L_A)
    x = jax.random.normal(jax.random.PRNGKey(7), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(8), (n, k))
    pw = ops.quantize(w, cb, cfg, impl="pallas", tile_m=64, tile_k=64)
    o_pl = ops.w4a4_linear_fused(
        x, pw, cb, cfg, impl="pallas", tile_m=64, tile_n=64, tile_k=64
    )
    assert o_pl.shape == (m, n)
    o_two = _two_launch(x, pw, cb, cfg, (64, 64, 64))
    np.testing.assert_array_equal(np.asarray(o_pl), np.asarray(o_two))


def test_interpret_autodetect_off_tpu():
    """interpret=None (the new default) resolves per backend — a bare call
    off-TPU runs interpret mode instead of failing to lower."""
    from repro.kernels.common import resolve_interpret

    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
    cfg = BCQConfig()
    cb = _codebooks(cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (128, 512))
    s_x = bcq.tensor_scale(x, cfg)
    ip, sp, rt = bcq_quantize_pallas(x, cb, s_x, cfg)  # no interpret arg
    ip2, sp2, rt2 = ref.quantize_ref(x, cb, cfg, s_x)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(rt2))


# ------------------------------------------------------ model-path regression
def test_qdense_packed_fused_vs_unfused_greedy():
    """quant_mode='packed' serving is token-for-token identical with the
    fused kernel path on vs off, end-to-end through greedy_generate."""
    from repro.configs.base import get_smoke
    from repro.data.pipeline import DataConfig, batch_at
    from repro.models import zoo
    from repro.models.layers import Runtime
    from repro.serving.generate import greedy_generate

    arch = get_smoke("gpt3_126m")
    bcfg = BCQConfig()
    cb = _codebooks(bcfg)
    rt0 = Runtime(quant_mode="none", compute_dtype=jnp.float32, param_dtype=jnp.float32)
    params = zoo.build(arch, rt0).init(jax.random.PRNGKey(0))
    packed = ptq.pack_params(params, cb, bcfg)
    packed["codebooks"] = cb
    prompts = batch_at(DataConfig(vocab=arch.vocab, seq_len=16, global_batch=2), 0)["tokens"]
    toks = {}
    for fused in (True, False):
        rt = Runtime(
            quant_mode="packed", bcq_cfg=bcfg, compute_dtype=jnp.float32,
            param_dtype=jnp.float32, fused_linear=fused,
        )
        api = zoo.build(arch, rt)
        toks[fused] = np.asarray(greedy_generate(api, packed, prompts, 6, 32))
    np.testing.assert_array_equal(toks[True], toks[False])


def test_qdense_shared_packed_nonbcq_act_keeps_unfused_path():
    """act_format='none' (W4A16) & friends are not implemented by the fused
    kernel — the shared packed path must keep honoring them (fused flag on
    or off gives identical outputs)."""
    import dataclasses as dc

    from repro.models.layers import Runtime, pack_weight, qdense_shared

    bcfg = BCQConfig()
    cb = _codebooks(bcfg)
    k, n = 128, 64
    x = jax.random.normal(jax.random.PRNGKey(12), (4, k))
    w = jax.random.normal(jax.random.PRNGKey(13), (k, n)) * 0.05
    p = {"kernel_packed": pack_weight(w, bcfg, cb)}
    base = Runtime(
        quant_mode="packed", bcq_cfg=bcfg, compute_dtype=jnp.float32,
        param_dtype=jnp.float32, act_format="none",
    )
    (y_fused,) = qdense_shared(x, [p], dc.replace(base, fused_linear=True), cb)
    (y_unf,) = qdense_shared(x, [p], dc.replace(base, fused_linear=False), cb)
    np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_unf))


def test_moe_packed_fused_matches_unfused():
    """Expert GEMMs: fused per-expert kernel vs decode+einsum (shared global
    activation s_X keeps the quantization identical)."""
    import dataclasses as dc

    from repro.models import moe as moe_lib
    from repro.models.layers import Runtime, pack_weight

    bcfg = BCQConfig()
    cb = _codebooks(bcfg)
    e, c, k, n = 2, 8, 128, 64
    xe = jax.random.normal(jax.random.PRNGKey(10), (e, c, k))
    wk = jax.random.normal(jax.random.PRNGKey(11), (e, k, n)) * 0.05
    packed = jax.vmap(lambda w: pack_weight(w, bcfg, cb))(wk)
    wp = {"kernel_packed": packed}
    base = Runtime(
        quant_mode="packed", bcq_cfg=bcfg, compute_dtype=jnp.float32,
        param_dtype=jnp.float32,
    )
    o_fused = moe_lib._expert_matmul(xe, wp, dc.replace(base, fused_linear=True), cb)
    o_unf = moe_lib._expert_matmul(xe, wp, dc.replace(base, fused_linear=False), cb)
    assert o_fused.shape == (e, c, n)
    np.testing.assert_allclose(np.asarray(o_fused), np.asarray(o_unf), rtol=1e-5, atol=1e-5)

#!/usr/bin/env python
"""Validate a chaos-run report (CI gate for fault containment).

  python tools/check_chaos.py CHAOS_REPORT.json [MORE.json ...]

The report comes from ``launch/serve.py --chaos --chaos-report PATH``
(docs/ROBUSTNESS.md).  The containment contract it enforces:

* **zero unhandled exceptions** — every injected fault was contained to
  a request; the engine loop never died;
* **zero leaked pages** — after the drain no page holds a reference
  (parked reclaimable prefix pages are retention, not leakage — the
  audit's partition law accounts for them);
* **clean final audit** — refcount ≡ table references, free/referenced/
  parked partition, prefix bijection, slot geometry;
* **every request finished** — each submitted rid landed in
  ``finished`` (possibly as several forked siblings), either clean or
  with a TYPED lifecycle error kind;
* **internal consistency** — counters agree with per-request outcomes,
  the fault log matches its by-site tally;
* **swap accounting** (host tier) — every swap-in either verified its
  integrity digest or quarantined its owner
  (``swap_ins == verified_swapins + corrupt_swapins``), the host pool
  drained back under its bound, and the cross-tier audit (one tier per
  page, pinned entries anchored, digests present) came back clean.

Only stdlib — runnable on artifacts downloaded from a CI run without
the repo's python path set up.  Exits nonzero on the first violation.
"""
from __future__ import annotations

import json
import sys

SCHEMA = 1
ERROR_KINDS = {
    "invalid", "too_long", "cancelled", "expired", "shed", "quarantined",
}
FAULT_SITES = {"alloc", "prefix_claim", "launch", "logits", "sampler",
               "swap_out", "swap_in", "swap_corrupt"}
SWAP_KEYS = ("swap_outs", "swap_ins", "verified_swapins", "corrupt_swapins",
             "swap_bytes", "swap_skips", "recompressed_pages")


def fail(msg: str) -> None:
    print(f"check_chaos: FAIL: {msg}", file=sys.stderr)
    raise SystemExit(1)


def check_report(path: str) -> None:
    with open(path) as f:
        rep = json.load(f)
    if rep.get("schema") != SCHEMA:
        fail(f"{path}: schema {rep.get('schema')!r} != {SCHEMA}")
    for section in ("final_audit", "health", "faults", "requests"):
        if section not in rep:
            fail(f"{path}: missing section {section!r}")

    # --- the containment contract ---------------------------------------
    if rep["unhandled_exception"] is not None:
        fail(f"{path}: unhandled exception escaped the engine: "
             f"{rep['unhandled_exception']}")
    if rep["leaked_pages"] != 0:
        fail(f"{path}: {rep['leaked_pages']} referenced page(s) after drain")
    audit = rep["final_audit"]
    if not audit["ok"]:
        fail(f"{path}: final audit dirty: {audit['violations']}")
    if not rep["all_finished"]:
        fail(f"{path}: some submitted requests never finished")
    if not rep["requests"]:
        fail(f"{path}: no finished requests recorded")

    # --- per-request outcomes --------------------------------------------
    for o in rep["requests"]:
        kind = o["error_kind"]
        if kind is not None and kind not in ERROR_KINDS:
            fail(f"{path}: rid {o['rid']} untyped error kind {kind!r}")
        if kind is None and o["n_out"] <= 0:
            fail(f"{path}: rid {o['rid']} finished clean with no output")

    # --- per-kind page accounting ----------------------------------------
    # one pool serves heterogeneous kinds: kv block-table pages (kv_paged
    # layout), state checkpoints + read-only shared encoder pages
    # (state_checkpoint layout).  After the drain only parked reclaimable
    # pages may stay live, and a layout must not hold the other's kinds.
    kinds = rep.get("pages_by_kind")
    if not isinstance(kinds, dict) or set(kinds) != {"kv", "state", "shared_ro"}:
        fail(f"{path}: pages_by_kind missing or malformed: {kinds!r}")
    if any(not isinstance(v, int) or v < 0 for v in kinds.values()):
        fail(f"{path}: negative/non-integer per-kind page count {kinds}")
    layout = rep.get("page_layout", "kv")
    wrong = {"kv": ("state", "shared_ro"), "state": ("kv",)}.get(layout, ())
    for k in wrong:
        if kinds[k] != 0:
            fail(f"{path}: layout {layout!r} holds {kinds[k]} {k!r} page(s)")

    # --- internal consistency --------------------------------------------
    faults = rep["faults"]
    if set(faults["by_site"]) - FAULT_SITES:
        fail(f"{path}: unknown fault sites {set(faults['by_site']) - FAULT_SITES}")
    if sum(faults["by_site"].values()) != faults["total"]:
        fail(f"{path}: fault by-site tally != total {faults['total']}")
    counters = rep["health"]["counters"]
    for key in ("quarantined", "shed", "expired", "cancelled",
                "audit_failures", "degraded_ticks"):
        if counters.get(key) is None or counters[key] < 0:
            fail(f"{path}: health counter {key!r} missing or negative")
    if counters["audit_failures"] != 0:
        fail(f"{path}: {counters['audit_failures']} periodic audit "
             f"failure(s) during the run")
    n_errored = sum(1 for o in rep["requests"] if o["error_kind"])
    n_counted = sum(
        counters[k] for k in ("quarantined", "shed", "expired", "cancelled")
    )
    if n_errored > n_counted:
        fail(f"{path}: {n_errored} errored requests but only {n_counted} "
             f"counted across the lifecycle counters")

    # --- host-tier swap accounting ---------------------------------------
    swap = rep["health"].get("swap")
    if not isinstance(swap, dict):
        fail(f"{path}: health has no swap-counter section")
    for key in SWAP_KEYS:
        if not isinstance(swap.get(key), int) or swap[key] < 0:
            fail(f"{path}: swap counter {key!r} missing or negative")
    if swap["swap_ins"] != swap["verified_swapins"] + swap["corrupt_swapins"]:
        fail(f"{path}: swap_ins={swap['swap_ins']} != verified "
             f"{swap['verified_swapins']} + corrupt {swap['corrupt_swapins']}")
    tier = rep["health"].get("host_tier")
    if rep.get("host_tier"):
        if not isinstance(tier, dict):
            fail(f"{path}: --host-tier run reported no host_tier health")
        if tier["used"] > tier["capacity"]:
            fail(f"{path}: host tier over capacity: {tier}")
        if tier["pinned"] != 0:
            fail(f"{path}: {tier['pinned']} pinned host entrie(s) survived "
                 f"the drain (leaked preemption carries)")
    elif swap["swap_outs"] or swap["swap_ins"]:
        fail(f"{path}: swap activity {swap} with the host tier disabled")

    errs: dict = {}
    for o in rep["requests"]:
        if o["error_kind"]:
            errs[o["error_kind"]] = errs.get(o["error_kind"], 0) + 1
    print(
        f"check_chaos: {path} OK (cache={rep['cache']}, layout={layout}, "
        f"seed={rep['chaos_seed']}, rate={rep['chaos_rate']}: "
        f"{len(rep['requests'])} finished / {rep['ticks']} ticks, "
        f"{faults['total']} faults {faults['by_site']}, errors {errs or '{}'}, "
        f"pages by kind {kinds}, 0 leaks, audit clean"
        + (f", swap {swap['swap_outs']}out/{swap['swap_ins']}in "
           f"[{swap['verified_swapins']}ok+{swap['corrupt_swapins']}corrupt]"
           if rep.get("host_tier") else "")
        + ")"
    )


def main(argv: list[str]) -> None:
    if not argv:
        fail("usage: check_chaos.py CHAOS_REPORT.json [MORE.json ...]")
    for path in argv:
        check_report(path)


if __name__ == "__main__":
    main(sys.argv[1:])

#!/usr/bin/env python
"""Validate serving telemetry artifacts (CI gate).

  python tools/check_telemetry.py METRICS.json [TRACE.json]

Checks the --metrics-json dump (schema version, required counters /
gauges / histograms with the pinned bucket edges, timeline sanity) and
the --trace-out Chrome trace (loadable, monotonic timestamps, every
duration Begin paired with an End, thread-name metadata).  Exits
nonzero with a message on the first violation so CI fails loudly.

Only stdlib — runnable on artifacts downloaded from a CI run without
the repo's python path set up.
"""
from __future__ import annotations

import json
import sys

SCHEMA = 1

REQUIRED_COUNTERS = (
    "prefix_hits", "prefix_misses", "preemptions", "prefix_evictions",
    "decode_ticks", "prefill_chunks", "prefill_tokens", "prefill_launches",
    "forks", "cow_copies", "shared_pages", "device_syncs",
    # robustness layer (docs/ROBUSTNESS.md)
    "quarantined", "shed", "expired", "cancelled",
    "audit_failures", "degraded_ticks",
    # host-RAM swap tier (zeros when the tier is disabled)
    "swap_outs", "swap_ins", "verified_swapins", "corrupt_swapins",
    "swap_bytes",
)
REQUIRED_GAUGES = (
    "pool_pages_used", "pool_pages_free", "pool_peak_pages",
    "prefix_reclaimable_pages", "prefix_registered_pages",
    "watermark_headroom", "queue_depth", "active_slots",
    # per-kind pool occupancy: one page budget shared across
    # heterogeneous kinds (kv block-table pages, state checkpoints,
    # read-only shared encoder pages)
    "pool_pages_kv", "pool_pages_state", "pool_pages_shared_ro",
    # host-RAM swap tier occupancy (zeros when disabled)
    "host_pages_used", "host_pages_capacity",
)
# name → exact bucket edges (mirrors repro.serving.telemetry — kept
# literal here so the checker stands alone)
REQUIRED_HISTOGRAMS = {
    "ttft_s": [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
               1.0, 2.5, 5.0, 10.0],
    "itl_s": [0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
              0.5, 1.0],
    "queue_time_s": [0.0001, 0.001, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0],
    "prefill_launch_s": None,  # = itl_s edges
    "decode_tick_s": None,
}
REQUIRED_HISTOGRAMS["prefill_launch_s"] = REQUIRED_HISTOGRAMS["itl_s"]
REQUIRED_HISTOGRAMS["decode_tick_s"] = REQUIRED_HISTOGRAMS["itl_s"]


def fail(msg: str) -> None:
    print(f"check_telemetry: FAIL: {msg}", file=sys.stderr)
    raise SystemExit(1)


def check_metrics(path: str) -> None:
    with open(path) as f:
        snap = json.load(f)
    if snap.get("schema") != SCHEMA:
        fail(f"{path}: schema {snap.get('schema')!r} != {SCHEMA}")
    if snap.get("level") not in ("counters", "default"):
        fail(f"{path}: unknown level {snap.get('level')!r}")
    for section in ("counters", "gauges", "histograms", "journal", "timelines"):
        if section not in snap:
            fail(f"{path}: missing section {section!r}")
    for name in REQUIRED_COUNTERS:
        if not isinstance(snap["counters"].get(name), int):
            fail(f"{path}: counter {name!r} missing or non-integer")
    for name in REQUIRED_GAUGES:
        if name not in snap["gauges"]:
            fail(f"{path}: gauge {name!r} missing")
    kinds = {k: snap["gauges"][f"pool_pages_{k}"]
             for k in ("kv", "state", "shared_ro")}
    if any(v < 0 for v in kinds.values()):
        fail(f"{path}: negative per-kind page gauge {kinds}")
    if sum(kinds.values()) != snap["gauges"]["pool_pages_used"]:
        fail(f"{path}: per-kind pages {kinds} do not sum to "
             f"pool_pages_used={snap['gauges']['pool_pages_used']}")
    # host-tier swap accounting: every swap-in either verified or
    # quarantined, and occupancy never exceeds the configured bound
    c = snap["counters"]
    if c["swap_ins"] != c["verified_swapins"] + c["corrupt_swapins"]:
        fail(f"{path}: swap_ins={c['swap_ins']} != verified "
             f"{c['verified_swapins']} + corrupt {c['corrupt_swapins']}")
    g = snap["gauges"]
    if g["host_pages_used"] > g["host_pages_capacity"]:
        fail(f"{path}: host_pages_used={g['host_pages_used']} exceeds "
             f"host_pages_capacity={g['host_pages_capacity']}")
    for name, edges in REQUIRED_HISTOGRAMS.items():
        h = snap["histograms"].get(name)
        if h is None:
            fail(f"{path}: histogram {name!r} missing")
        if h["buckets"] != edges:
            fail(f"{path}: histogram {name!r} buckets {h['buckets']} != {edges}")
        if len(h["counts"]) != len(edges) + 1:  # implicit +inf bucket
            fail(f"{path}: histogram {name!r} has {len(h['counts'])} counts "
                 f"for {len(edges)} edges")
        if sum(h["counts"]) != h["count"]:
            fail(f"{path}: histogram {name!r} bucket counts do not sum "
                 f"to count={h['count']}")
    for tl in snap["timelines"]["requests"]:
        if tl["ttft_s"] is not None and tl["ttft_s"] < 0:
            fail(f"{path}: rid {tl['rid']} negative ttft {tl['ttft_s']}")
        if tl["n_tokens"] < 0 or tl["preemptions"] < 0:
            fail(f"{path}: rid {tl['rid']} negative token/preempt counts")
    if "quant_probes" in snap:
        qp = snap["quant_probes"]
        for site, layers in qp["sites"].items():
            for layer, agg in layers.items():
                if agg["nmse_mean"] < 0 or agg["nmse_max"] < 0:
                    fail(f"{path}: probe {site}/L{layer} negative nmse")
                if any(c < 0 for c in agg["cluster_occupancy"]):
                    fail(f"{path}: probe {site}/L{layer} negative occupancy")
    print(f"check_telemetry: {path} OK "
          f"(level={snap['level']}, {len(snap['counters'])} counters, "
          f"{snap['timelines']['count']} timelines"
          + (", quant probes present" if "quant_probes" in snap else "")
          + ")")


def check_trace(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        fail(f"{path}: no traceEvents list")
    if doc.get("otherData", {}).get("schema") != SCHEMA:
        fail(f"{path}: otherData.schema != {SCHEMA}")
    meta_threads = {
        e["args"]["name"] for e in evs
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    if not {"host scheduling", "device launches"} <= meta_threads:
        fail(f"{path}: thread-name metadata missing ({meta_threads})")
    real = [e for e in evs if e["ph"] != "M"]
    last_ts = -1.0
    depth: dict = {}
    for e in real:
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in e:
                fail(f"{path}: event missing {key!r}: {e}")
        if e["ts"] < last_ts:
            fail(f"{path}: timestamps not monotonic at {e}")
        last_ts = e["ts"]
        if e["ph"] == "B":
            depth[e["tid"]] = depth.get(e["tid"], 0) + 1
        elif e["ph"] == "E":
            depth[e["tid"]] = depth.get(e["tid"], 0) - 1
            if depth[e["tid"]] < 0:
                fail(f"{path}: End without Begin on tid {e['tid']}")
        elif e["ph"] != "i":
            fail(f"{path}: unexpected phase {e['ph']!r}")
    if any(d != 0 for d in depth.values()):
        fail(f"{path}: unbalanced spans at end of trace: {depth}")
    spans = sum(1 for e in real if e["ph"] == "B")
    print(f"check_telemetry: {path} OK ({spans} spans, "
          f"{sum(1 for e in real if e['ph'] == 'i')} instants, "
          f"{doc['otherData']['dropped']} dropped)")


def main(argv: list[str]) -> None:
    if not 1 <= len(argv) <= 2:
        fail("usage: check_telemetry.py METRICS.json [TRACE.json]")
    check_metrics(argv[0])
    if len(argv) == 2:
        check_trace(argv[1])


if __name__ == "__main__":
    main(sys.argv[1:])
